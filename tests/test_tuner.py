"""repro.tuner: the measured-cost ClipPlan and its decision-override plumbing.

Covers the Eq-(4.1) boundary cases, the Remark-4.1 time variant, plan JSON
round-trip + stale-plan rejection, the max-batch search, and the subsystem's
correctness oracle: clipped gradients under a (even adversarially flipped)
plan must match the analytic ``mixed_ghost`` exactly — the branch choice is
pure cost, never math.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.decision import decide, ghost_is_cheaper
from repro.core.taps import Ctx, TapMeta
from repro.nn.module import Dense
from repro.tuner import (
    ClipPlan,
    MeasureConfig,
    build_plan,
    derive_accumulation,
    device_string,
    find_max_physical_batch,
    max_batch_by_memory,
    remeasure_at_batch,
    shape_fingerprint,
)

from helpers import max_tree_diff


def _meta(kind="matmul", T=8, D=16, p=4, batch=2):
    return TapMeta(
        kind=kind, T=T, D=D, p=p, s_shape=(batch, T, p), s_dtype=jnp.float32,
        param_path="w", batch_size=batch,
    )


# ---------------------------------------------------------------- decision --
def test_eq41_tie_prefers_instantiate():
    # 2T^2 == pD is NOT strictly cheaper: the paper's rule picks instantiate.
    T, p, D = 4, 2, 16
    assert 2 * T * T == p * D
    assert not ghost_is_cheaper(T, D, p, by="space")
    assert decide(_meta(T=T, D=D, p=p), mode="mixed_ghost") == "instantiate"


def test_remark41_time_variant_differs_from_space():
    # T=2, D=16, p=1: space rule 2T^2=8 < pD=16 -> ghost, but the time rule
    # 2T^2(D+p+1) = 144 >= 2(T+1)pD = 96 -> instantiate.
    assert ghost_is_cheaper(2, 16, 1, by="space")
    assert not ghost_is_cheaper(2, 16, 1, by="time")
    m = _meta(T=2, D=16, p=1)
    assert decide(m, mode="mixed_ghost", by="space") == "ghost"
    assert decide(m, mode="mixed_ghost", by="time") == "instantiate"


def test_plan_override_wins_over_analytic_rule():
    m = _meta(T=1, D=64, p=64)  # analytic: 2 < 4096 -> ghost
    assert decide(m, mode="mixed_ghost") == "ghost"
    assert decide(m, mode="mixed_ghost", override="instantiate") == "instantiate"
    assert decide(m, mode="mixed_ghost", override="ghost") == "ghost"
    with pytest.raises(ValueError):
        decide(m, mode="mixed_ghost", override="banana")


def test_override_never_wins_over_forced_kinds():
    # embedding/scale taps have exactly one viable norm computation
    emb = _meta(kind="embedding")
    assert decide(emb, override="instantiate") == "ghost"
    scale = _meta(kind="scale")
    assert decide(scale, override="ghost") == "instantiate"


def test_override_never_wins_over_reference_modes():
    # the pure modes exist to measure a fixed branch everywhere; a plan must
    # not silently turn a 'ghost' benchmark into mixed execution
    m = _meta(T=1, D=64, p=64)
    assert decide(m, mode="ghost", override="instantiate") == "ghost"
    assert decide(m, mode="fastgradclip", override="ghost") == "instantiate"


def test_bk_branch_rule_is_bank_size_driven():
    # lm_head-like: T small, pD huge -> the (a, g) book is far smaller than
    # per-sample gradient instantiation
    m = _meta(T=1, D=64, p=4096)
    assert decide(m, mode="bk_mixed") == "ghost"
    # conv-like: T large, pD small -> bank the per-sample gradients
    m = _meta(T=1024, D=27, p=32)
    assert decide(m, mode="bk_mixed") == "instantiate"
    # the two rules legitimately disagree: Eq 4.1 only weighs the norm
    # computation (2T^2 vs pD); bk also has to HOLD the book, so a tap can
    # be ghost-cheap to norm yet psg-cheap to bank
    m = _meta(T=16, D=32, p=32)
    assert decide(m, mode="mixed_ghost") == "ghost"  # 2T^2 = 512 < pD = 1024
    # book = T(D+p) + 2T^2 = 1024 + 512 = 1536 >= pD = 1024
    assert decide(m, mode="bk_mixed") == "instantiate"
    # raw-activation awareness: a stride-2 conv's raw input is ~2.25x smaller
    # than its unfolded patches, flipping the book back to affordable
    conv_meta = dataclasses.replace(
        _meta(T=64, D=576, p=64, batch=2),
        a_shape=(2, 16, 16, 64), a_dtype=jnp.float32,
    )
    # book = 16*16*64 + 64*64 + 2*64^2 = 28672 < pD = 36864
    assert decide(conv_meta, mode="bk_mixed") == "ghost"
    # unfolded-size fallback (no recorded activation shape): instantiate
    # book = 64*576 + 64*64 + 8192 = 49152 >= 36864
    no_a = _meta(T=64, D=576, p=64)
    assert decide(no_a, mode="bk_mixed") == "instantiate"


def test_bk_override_wins_and_stays_exact_branchwise():
    m = _meta(T=1, D=64, p=4096)
    assert decide(m, mode="bk_mixed", override="instantiate") == "instantiate"
    with pytest.raises(ValueError):
        decide(m, mode="bk_mixed", override="banana")


# -------------------------------------------------------------------- plan --
def _tiny_metas():
    return {
        "a/out": _meta(T=8, D=16, p=4),
        "b/out": _meta(T=2, D=32, p=32),
        "emb/out": _meta(kind="embedding", T=8, D=1, p=16),
    }


def test_clipplan_json_round_trip(tmp_path):
    metas = _tiny_metas()
    plan = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=(("a/out", "instantiate"), ("b/out", "ghost")),
        bk_branches=(("a/out", "instantiate"), ("b/out", "instantiate")),
        physical_batch=64,
        logical_batch=256,
        accumulation_steps=4,
        measured_at_physical=True,
        arch="tiny",
        timings=(("a/out", 10.0, 5.0, 9.0, 6.0, 20.0),
                 ("b/out", 3.0, 7.0, 8.0, 4.0, 12.0)),
    )
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = ClipPlan.load(path)
    assert loaded == plan
    assert loaded.branch_map() == {"a/out": "instantiate", "b/out": "ghost"}
    assert loaded.branch_map("bk_mixed") == {
        "a/out": "instantiate", "b/out": "instantiate"
    }
    # the artifact is plain JSON, inspectable by other tooling
    raw = json.loads(open(path).read())
    assert raw["physical_batch"] == 64
    assert raw["measured_at_physical"] is True


def test_clipplan_mode_costs_and_recommendation():
    # mixed_ghost: min(10,5)+20 + min(3,7)+12 = 40; bk: min(9,6)+min(8,4)=10
    plan = ClipPlan(
        fingerprint="f", device="d",
        timings=(("a", 10.0, 5.0, 9.0, 6.0, 20.0),
                 ("b", 3.0, 7.0, 8.0, 4.0, 12.0)),
    )
    assert plan.mode_cost_us("mixed_ghost") == 40.0
    assert plan.mode_cost_us("bk_mixed") == 10.0
    assert plan.recommended_mode() == "bk_mixed"
    assert ClipPlan(fingerprint="f", device="d").recommended_mode() == "mixed_ghost"


def test_clipplan_rejects_bad_json():
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({"fingerprint": "x", "device": "y",
                                       "version": 99}))
    # pre-three-way (v1) artifacts are stale by construction: their branch
    # maps know nothing about the bk bank decision
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({"fingerprint": "x", "device": "y",
                                       "version": 1}))
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({
            "fingerprint": "x", "device": "y", "version": 2,
            "branches": [["a", "banana"]],
        }))
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({
            "fingerprint": "x", "device": "y", "version": 2,
            "bk_branches": [["a", "banana"]],
        }))


def test_stale_plan_rejected_falls_back_to_analytic():
    metas = _tiny_metas()
    good = ClipPlan(
        fingerprint=shape_fingerprint(metas), device=device_string(),
        branches=(("a/out", "instantiate"),),
        bk_branches=(("a/out", "ghost"), ("b/out", "ghost")),
    )
    assert good.overrides_for(metas) == {"a/out": "instantiate"}
    # mode-specific maps: bk_mixed reads the bank branches
    assert good.overrides_for(metas, mode="bk_mixed") == {
        "a/out": "ghost", "b/out": "ghost"
    }

    # different shapes (stale fingerprint) -> no overrides
    stale = dataclasses.replace(good, fingerprint="deadbeefdeadbeef")
    assert stale.overrides_for(metas) == {}

    # different device -> no overrides
    wrong_dev = dataclasses.replace(good, device="tpu:TPU v9")
    assert wrong_dev.overrides_for(metas) == {}

    # fingerprint tracks shapes: changing one tap's D changes it
    other = dict(metas, **{"a/out": _meta(T=8, D=32, p=4)})
    assert shape_fingerprint(other) != shape_fingerprint(metas)
    # but not the batch size (plans transfer across physical batch)
    rebatched = dict(metas, **{"a/out": _meta(T=8, D=16, p=4, batch=64)})
    assert shape_fingerprint(rebatched) == shape_fingerprint(metas)


# --------------------------------------------------------------- max batch --
def test_find_max_physical_batch_is_exact():
    for threshold in (1, 2, 37, 64, 100):
        calls = []

        def fits(b, t=threshold):
            calls.append(b)
            return b <= t

        assert find_max_physical_batch(fits, hi_cap=128) == min(threshold, 128)
    assert find_max_physical_batch(lambda b: False, hi_cap=128) == 0
    assert find_max_physical_batch(lambda b: True, hi_cap=128) == 128


def test_derive_accumulation_invariants():
    for logical, max_phys in [(256, 96), (256, 64), (8, 64), (7, 2), (1, 1)]:
        physical, steps = derive_accumulation(logical, max_phys)
        assert physical <= max_phys
        assert physical * steps >= logical
        # steps is minimal: one fewer microstep cannot cover the logical batch
        assert (steps - 1) * max_phys < logical
    with pytest.raises(ValueError):
        derive_accumulation(0, 4)
    with pytest.raises(ValueError):
        derive_accumulation(4, 0)


# --------------------------------------------- end-to-end correctness oracle --
class TwoLayer:
    """Tiny model with one ghost-leaning and one instantiate-leaning tap."""

    def __init__(self):
        self.f1 = Dense("f1", 12, 8)
        self.f2 = Dense("f2", 8, 4)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"f1": self.f1.init(k1), "f2": self.f2.init(k2)}

    def loss_with_ctx(self, params, batch, ctx: Ctx):
        h = jax.nn.relu(self.f1(params["f1"], batch["x"], ctx.scope("f1")))
        out = self.f2(params["f2"], h, ctx.scope("f2"))
        return jnp.mean((out - batch["y"]) ** 2, axis=(1, 2))


def _two_layer_setup():
    model = TwoLayer()
    params = model.init(jax.random.PRNGKey(0))
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    batch = {
        "x": jax.random.normal(k1, (4, 6, 12)),
        "y": jax.random.normal(k2, (4, 6, 4)),
    }
    return model, params, batch


@pytest.mark.parametrize(
    "mode", ["mixed_ghost", "mixed_ghost_taps", "bk_mixed", "bk_mixed_taps"]
)
def test_plan_changes_branch_not_math(mode):
    """Clipped grads under an adversarially flipped three-way plan == analytic.

    Both branch maps are inverted: the norm branch of the second-backward
    modes AND the bank branch of book-keeping.  Either way the math is
    identical — the plan moves cost, never results.
    """
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)

    def flip(branch):
        return "instantiate" if branch == "ghost" else "ghost"

    flipped = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=tuple(
            (n, flip(decide(m, mode="mixed_ghost")))
            for n, m in sorted(metas.items()) if m.kind == "matmul"
        ),
        bk_branches=tuple(
            (n, flip(decide(m, mode="bk_mixed")))
            for n, m in sorted(metas.items()) if m.kind == "matmul"
        ),
    )
    f_analytic = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode))
    f_plan = dp_value_and_clipped_grad(
        model.loss_with_ctx, ClipConfig(mode=mode, plan=flipped)
    )
    l1, g1, a1 = f_analytic(params, batch)
    l2, g2, a2 = f_plan(params, batch)
    assert float(l1) == float(l2)
    assert jnp.allclose(a1["per_sample_norms"], a2["per_sample_norms"], atol=1e-5)
    assert max_tree_diff(g1, g2) < 1e-5


@pytest.mark.parametrize("mode", ["mixed_ghost", "bk_mixed", "bk_mixed_taps"])
def test_plan_kernel_choice_changes_no_output(mode):
    """Flipping the plan-recorded kernel impl (v5 ``kernels``) re-routes the
    hot ops through the other implementation without changing any output —
    the acceptance oracle for the dispatch layer."""
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    from repro.tuner.measure import KERNEL_OPS_BY_KIND

    def plan_with(impl):
        return ClipPlan(
            fingerprint=shape_fingerprint(metas),
            device=device_string(),
            kernels=tuple(
                (n, op, impl)
                for n, m in sorted(metas.items())
                for op in KERNEL_OPS_BY_KIND.get(m.kind, ())
            ),
        )

    outs = {}
    for impl in ("xla", "pallas"):
        fn = dp_value_and_clipped_grad(
            model.loss_with_ctx, ClipConfig(mode=mode, plan=plan_with(impl))
        )
        outs[impl] = fn(params, batch)
    l_x, g_x, aux_x = outs["xla"]
    l_p, g_p, aux_p = outs["pallas"]
    assert jnp.allclose(l_x, l_p, rtol=1e-6)
    assert jnp.allclose(
        aux_x["per_sample_norms"], aux_p["per_sample_norms"], atol=1e-5
    )
    assert max_tree_diff(g_x, g_p) < 1e-5


def test_plan_v5_kernels_round_trip_and_staleness(tmp_path):
    metas = _tiny_metas()
    plan = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        kernels=(("a/out", "ghost_norm", "xla"),
                 ("a/out", "psg_contract", "xla"),
                 ("emb/out", "embedding_ghost_norm", "xla")),
    )
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = ClipPlan.load(path)
    assert loaded == plan
    assert loaded.kernel_map() == {
        "a/out": {"ghost_norm": "xla", "psg_contract": "xla"},
        "emb/out": {"embedding_ghost_norm": "xla"},
    }
    assert loaded.kernels_for(metas) == loaded.kernel_map()
    # stale fingerprint or wrong device -> {} (dispatch backend default)
    stale = dataclasses.replace(loaded, fingerprint="deadbeefdeadbeef")
    assert stale.kernels_for(metas) == {}
    wrong_dev = dataclasses.replace(loaded, device="tpu:TPU v9")
    assert wrong_dev.kernels_for(metas) == {}
    # RATIFYING a fleet agreement is enough for branch overrides but NOT
    # for the kernel map: impls are backend-specific, and a pallas winner
    # measured on the fleet's TPU kind must not trace the interpreter on
    # the ratifying kinds
    ratified = dataclasses.replace(
        loaded, device="tpu:TPU v9", devices=(device_string(),),
        branches=(("a/out", "ghost"),),
        kernels=(("a/out", "ghost_norm", "pallas"),),
    )
    assert ratified.overrides_for(metas) == {"a/out": "ghost"}
    assert ratified.kernels_for(metas) == {}
    # the kernel map is covered by the consensus hash: a fleet cannot mix
    flipped = dataclasses.replace(
        loaded, kernels=(("a/out", "ghost_norm", "pallas"),) + loaded.kernels[1:]
    )
    assert flipped.consensus_hash() != loaded.consensus_hash()
    # invalid impls and unknown ops are rejected at parse time — a typo'd
    # op would otherwise load cleanly and silently never take effect
    bad = json.loads(plan.to_json())
    bad["kernels"] = [["a/out", "ghost_norm", "banana"]]
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps(bad))
    bad["kernels"] = [["a/out", "ghost_nrm", "pallas"]]
    with pytest.raises(ValueError, match="unknown kernel op"):
        ClipPlan.from_json(json.dumps(bad))
    # v4 artifacts (no kernels key) migrate with an empty map
    v4 = json.loads(plan.to_json())
    del v4["kernels"]
    v4["version"] = 4
    assert ClipPlan.from_json(json.dumps(v4)).kernels == ()


def test_build_plan_records_kernel_choices():
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    from repro.kernels import dispatch
    from repro.tuner.measure import KERNEL_OPS_BY_KIND

    plan = build_plan(
        metas, measure=MeasureConfig(repeats=1, warmup=1), arch="twolayer"
    )
    kmap = plan.kernel_map()
    expected_taps = {
        n for n, m in metas.items() if m.kind in KERNEL_OPS_BY_KIND
    }
    assert set(kmap) == expected_taps
    for n, ks in kmap.items():
        assert set(ks) == set(KERNEL_OPS_BY_KIND[metas[n].kind])
        for impl in ks.values():
            assert impl in dispatch.available_impls()


def test_measured_plan_round_trips_through_engine(tmp_path):
    """build_plan -> save -> ClipConfig(plan=...) produces analytic-equal grads."""
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    plan = build_plan(
        metas, measure=MeasureConfig(repeats=1, warmup=1), arch="twolayer"
    )
    assert set(plan.branch_map()) == {
        n for n, m in metas.items() if m.kind == "matmul"
    }
    path = str(tmp_path / "plan.json")
    plan.save(path)
    plan = ClipPlan.load(path)

    f_analytic = jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    )
    f_plan = jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(plan=plan))
    )
    _, g1, _ = f_analytic(params, batch)
    _, g2, _ = f_plan(params, batch)
    assert max_tree_diff(g1, g2) < 1e-5


def test_measure_tap_conv_times_real_bk_kernels():
    """Conv taps must time the kernels the engine actually runs: the psg bank
    goes through the conv op's vjp on raw activations (no im2col)."""
    from repro.core.taps import Ctx
    from repro.nn.conv import Conv2d, global_avg_pool

    conv = Conv2d("c", 3, 8, (3, 3), strides=(2, 2), padding="SAME")
    head = Dense("head", 8, 5)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"c": conv.init(k1), "head": head.init(k2)}

    def loss(params, batch, ctx):
        h = conv(params["c"], batch["image"], ctx.scope("c"))
        h = global_avg_pool(h)
        out = head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]
        return jnp.sum(out * out, axis=-1)

    batch = {"image": jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))}
    metas = discover_meta(loss, params, batch)
    (conv_meta,) = [m for m in metas.values() if m.conv is not None]
    assert conv_meta.a_shape == (2, 8, 8, 3)
    from repro.tuner.measure import measure_tap

    t = measure_tap(conv_meta, MeasureConfig(repeats=1, warmup=1, max_rows=2))
    for v in (t.ghost_us, t.instantiate_us, t.bk_ghost_us,
              t.bk_instantiate_us, t.second_bwd_us):
        assert v > 0.0


def test_remeasure_at_physical_batch_closes_the_loop():
    """ROADMAP loop: after max_batch settles, branch timings are re-taken at
    the tuned physical batch and only then does the plan finalize."""
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    cfg = MeasureConfig(repeats=1, warmup=1, max_rows=2)
    plan = build_plan(metas, measure=cfg, arch="twolayer")
    assert not plan.measured_at_physical
    plan2 = remeasure_at_batch(plan, metas, 8, cfg)
    assert plan2.measured_at_physical
    # batch-free identity: the refreshed plan stays valid for the model
    assert plan2.fingerprint == plan.fingerprint
    assert plan2.matches(metas)
    assert set(dict(plan2.branches)) == set(dict(plan.branches))
    assert set(dict(plan2.bk_branches)) == set(dict(plan.bk_branches))


def test_engine_tune_remeasures_at_tuned_batch(tmp_path, monkeypatch):
    from repro.core.engine import PrivacyEngine

    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch = _two_layer_setup()
    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    plan = eng.tune(params, batch, arch="twolayer", plan_path=None,
                    use_cache=False, measure=MeasureConfig(repeats=1, warmup=1),
                    budget_bytes=1 << 30, hi_cap=16)
    assert plan.physical_batch == 16
    assert plan.measured_at_physical


def test_engine_tune_cache_hit(tmp_path, monkeypatch):
    """A second tune() for the same (arch, device, shapes) skips profiling."""
    from repro.core.engine import PrivacyEngine

    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch = _two_layer_setup()
    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    p1 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1))
    p2 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1))
    assert p1 == p2  # identical object state: timings were not re-measured
    assert eng.plan == p1
    # use_cache=False forces a re-measure (timings will differ)
    p3 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1), use_cache=False,
                  plan_path=None)
    assert p3.fingerprint == p1.fingerprint


def test_noise_finalize_non_private_matches_train_step():
    """Accumulation finalize must not noise/rescale non_private runs."""
    from repro.launch.steps import DPTrainConfig, make_noise_finalize
    from repro.optim import adam, warmup_cosine

    model, params, batch = _two_layer_setup()
    opt = adam()
    dp = DPTrainConfig(clipping_mode="non_private", noise_multiplier=123.0,
                       logical_batch=4)
    fin = make_noise_finalize(opt, warmup_cosine(1e-3, 1, 10), dp)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32), "rng": jax.random.PRNGKey(0)}
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    out1 = fin(dict(state), grads)
    out2 = fin(dict(state), grads)
    # no Gaussian noise: identical grads give identical (deterministic) updates
    assert max_tree_diff(out1["params"], out2["params"]) == 0.0


def test_max_batch_by_memory_monotone_model():
    model, params, batch = _two_layer_setup()
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    # generous budget: search caps out at hi_cap
    assert max_batch_by_memory(
        grad_fn, params, batch, budget_bytes=1 << 34, hi_cap=8
    ) == 8
    # zero budget: nothing fits
    assert max_batch_by_memory(
        grad_fn, params, batch, budget_bytes=0, hi_cap=8
    ) == 0


# ------------------------------------------------- trial-based max batch --
def test_max_batch_trial_survives_simulated_oom():
    """The retry ladder reports 'does not fit' and keeps the process alive."""
    from repro.tuner import max_batch_by_trial

    model, params, batch = _two_layer_setup()
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    calls = []

    def runner(b):
        calls.append(b)
        if b > 6:
            raise RuntimeError(
                "RESOURCE_EXHAUSTED: Out of memory while trying to allocate"
            )

    got = max_batch_by_trial(
        grad_fn, params, batch, budget_bytes=None, hi_cap=64, runner=runner
    )
    assert got == 6
    # every failing size was retried once (ladder) before being ruled out
    assert calls.count(8) == 2 and calls.count(7) == 2
    # non-OOM failures must NOT be swallowed as "does not fit"
    def broken(b):
        raise ValueError("shape mismatch")

    with pytest.raises(ValueError):
        max_batch_by_trial(
            grad_fn, params, batch, budget_bytes=None, hi_cap=4, runner=broken
        )


def test_max_batch_trial_retries_transient_oom():
    """One flaky OOM (fragmentation) recovers; only a repeat rules a size out."""
    from repro.tuner.max_batch import trial_survives

    failed_once = set()

    def flaky(b):
        if b not in failed_once:
            failed_once.add(b)
            raise RuntimeError("RESOURCE_EXHAUSTED")

    assert trial_survives(flaky, 8, attempts=2)

    def always(b):
        raise RuntimeError("RESOURCE_EXHAUSTED")

    assert not trial_survives(always, 8, attempts=2)


def test_max_batch_trial_converges_to_memory_model():
    """When both drivers apply (CPU executions always fit; the budget binds
    through the pre-filter), the trial search lands on the memory answer."""
    from repro.tuner import max_batch_by_trial

    model, params, batch = _two_layer_setup()
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    for budget in (1 << 34, 1 << 22):
        by_mem = max_batch_by_memory(
            grad_fn, params, batch, budget_bytes=budget, hi_cap=8
        )
        by_trial = max_batch_by_trial(
            grad_fn, params, batch, budget_bytes=budget, hi_cap=8
        )
        assert by_trial == by_mem


def test_certify_max_batch_method_selection(monkeypatch):
    """Concrete arrays certify by execution; specs fall back to the model."""
    from repro.tuner import certify_max_batch

    model, params, batch = _two_layer_setup()
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    b, method = certify_max_batch(
        grad_fn, params, batch, budget_bytes=1 << 34, hi_cap=8
    )
    assert (b, method) == (8, "trial")

    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, batch)
    )
    b2, method2 = certify_max_batch(
        grad_fn, specs[0], specs[1], budget_bytes=1 << 34, hi_cap=8
    )
    assert (b2, method2) == (8, "memory")
    # explicit trial on specs is a hard error, not a silent fallback
    with pytest.raises(ValueError):
        certify_max_batch(
            grad_fn, specs[0], specs[1], budget_bytes=1 << 34, hi_cap=8,
            method="trial",
        )
    # env override forces the model even with concrete arrays
    monkeypatch.setenv("REPRO_MAX_BATCH_METHOD", "memory")
    _, method3 = certify_max_batch(
        grad_fn, params, batch, budget_bytes=1 << 34, hi_cap=8
    )
    assert method3 == "memory"


def test_remeasure_at_batch_reraces_stale_kernel_winners():
    """Plan staleness: kernel winners recorded at the probe batch are NOT
    carried into the certified-batch plan — remeasure re-races them there."""
    from repro.tuner.plan import KERNEL_IMPLS

    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    cfg = MeasureConfig(repeats=1, warmup=1, max_rows=2)
    plan = build_plan(metas, measure=cfg, arch="twolayer")
    assert plan.kernels  # v5 plans always record the raced winners
    # poison the recorded winners with an impl the race could never pick
    # here (pallas is TPU-only; this host races xla alone)
    stale = dataclasses.replace(
        plan,
        kernels=tuple((n, op, "pallas") for n, op, _ in plan.kernels),
    )
    fresh = remeasure_at_batch(stale, metas, 8, cfg)
    assert fresh.measured_at_physical
    # same taps/ops covered, every winner re-raced to a locally valid impl
    assert {(n, op) for n, op, _ in fresh.kernels} == {
        (n, op) for n, op, _ in plan.kernels
    }
    assert all(impl in KERNEL_IMPLS for _, _, impl in fresh.kernels)
    assert all(impl != "pallas" for _, _, impl in fresh.kernels)


def test_accum_microsteps_match_full_train_step():
    """Donated-accumulator path == one train_step on the full logical batch.

    Two microbatches of 2 folded through make_accum_microstep (scattered
    norms, summed grads) and finalized must reproduce the single-shot
    make_train_step update: same rng split discipline -> identical noise,
    per-sample clipping -> grad sums equal, metrics (loss, clip_frac)
    equal.  This is the correctness half of the donation/overlap change.
    """
    from repro.launch.steps import (
        DPTrainConfig,
        make_accum_finalize,
        make_accum_init,
        make_accum_microstep,
        make_clipped_microstep,
        make_train_step,
    )
    from repro.optim import adam, warmup_cosine
    from repro.policies.fixed import FixedPolicy

    model, params, batch = _two_layer_setup()  # logical batch of 4
    opt = adam()
    sched = warmup_cosine(1e-3, 1, 10)
    dp = DPTrainConfig(clipping_mode="mixed_ghost", clip_norm=1.0,
                       noise_multiplier=0.7, logical_batch=4,
                       accumulation_steps=2)
    policy = FixedPolicy(clip_norm=1.0, clip_fn="abadi")
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32), "rng": jax.random.PRNGKey(7),
             "policy": policy.init_state()}

    full_state, full_metrics = make_train_step(model, opt, sched, dp)(
        dict(state), batch
    )

    half = jax.tree_util.tree_map(lambda x: x[:2], batch)
    g_spec = jax.eval_shape(
        make_clipped_microstep(model, dp), params, half, state["policy"]
    )[1]
    acc = make_accum_init(g_spec, 4)()
    micro = make_accum_microstep(model, dp)
    for i in range(2):
        sub = jax.tree_util.tree_map(lambda x: x[i * 2:(i + 1) * 2], batch)
        acc = micro(state["params"], state["policy"], acc, sub,
                    jnp.asarray(i, jnp.int32))
    acc_state, acc_metrics = make_accum_finalize(opt, sched, dp)(
        dict(state), acc
    )

    assert max_tree_diff(acc_state["params"], full_state["params"]) < 1e-5
    assert max_tree_diff(acc_state["opt"], full_state["opt"]) < 1e-5
    assert abs(float(acc_metrics["loss"]) - float(full_metrics["loss"])) < 1e-5
    assert abs(float(acc_metrics["clip_frac"])
               - float(full_metrics["clip_frac"])) < 1e-6
