"""Optimizers, schedules, checkpointing, data pipeline, runtime helpers."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch, synthetic_vision_batch
from repro.optim import adam, apply_updates, sgd, warmup_cosine, warmup_linear
from repro.runtime.elastic import elastic_plan
from repro.runtime.fault import PreemptionHandler, StepWatchdog, retry


def test_adam_single_step_closed_form():
    params = {"w": jnp.zeros((3,))}
    opt = adam(b1=0.9, b2=0.999, eps=1e-8)
    st = opt.init(params)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    upd, st = opt.update(g, st, params, jnp.asarray(0), 0.1)
    # after bias correction, first step is -lr * sign-ish: -lr*g/(|g|+eps)
    want = -0.1 * g["w"] / (jnp.abs(g["w"]) + 1e-8)
    assert jnp.allclose(upd["w"], want, atol=1e-5)


def test_adamw_decay_direction():
    params = {"w": jnp.ones((2,))}
    opt = adam(weight_decay=0.1)
    st = opt.init(params)
    g = {"w": jnp.zeros((2,))}
    upd, _ = opt.update(g, st, params, jnp.asarray(0), 0.5)
    assert jnp.allclose(upd["w"], -0.5 * 0.1 * params["w"])


def test_sgd_momentum():
    params = {"w": jnp.zeros((1,))}
    opt = sgd(momentum=0.9)
    st = opt.init(params)
    g = {"w": jnp.ones((1,))}
    upd1, st = opt.update(g, st, params, jnp.asarray(0), 1.0)
    upd2, st = opt.update(g, st, params, jnp.asarray(1), 1.0)
    assert float(upd1["w"][0]) == -1.0
    assert abs(float(upd2["w"][0]) + 1.9) < 1e-6


def test_schedules_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50))
    lin = warmup_linear(1.0, 10, 100)
    assert abs(float(lin(100))) < 1e-6


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"params": {"a": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(7)}
    save_checkpoint(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    step, restored = restore_checkpoint(tmp_path)
    assert step == 7
    assert np.allclose(restored["params"]["a"], np.arange(6.0).reshape(2, 3))
    # no temp litter
    assert not [p for p in pathlib.Path(tmp_path).iterdir() if p.name.startswith(".tmp")]


def test_checkpoint_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2, async_save=False)
    for step in range(1, 5):
        mgr.save(step, {"x": jnp.asarray(step)})
    steps = sorted(
        int(p.stem.split("_")[1]) for p in tmp_path.iterdir() if p.suffix == ".npz"
    )
    assert steps == [3, 4]
    assert mgr.latest() == 4


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints restore onto a different sharding layout (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import _make_mesh

    state = {"w": jnp.arange(8.0)}
    save_checkpoint(tmp_path, 1, state)
    mesh = _make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    _, restored = restore_checkpoint(tmp_path, shardings=sh)
    assert restored["w"].sharding == sh["w"]


def test_synthetic_determinism_and_learnability():
    cfg = SyntheticLMConfig(vocab=64, seq_len=16, batch=4, seed=3)
    b1 = synthetic_lm_batch(cfg, 5, 0)
    b2 = synthetic_lm_batch(cfg, 5, 0)
    b3 = synthetic_lm_batch(cfg, 6, 0)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    # markov structure: most next-tokens follow the deterministic map
    nxt = (b1["tokens"] * cfg.markov_mult + 7) % cfg.vocab
    frac = float(jnp.mean((nxt == b1["labels"]).astype(jnp.float32)))
    assert frac > 0.7


def test_vision_batch_shapes():
    b = synthetic_vision_batch(batch=3, image=8, channels=3, n_classes=5, step=0)
    assert b["image"].shape == (3, 8, 8, 3)
    assert b["label"].shape == (3,)


def test_pipeline_prefetch_and_seek():
    cfg = SyntheticLMConfig(vocab=32, seq_len=8, batch=2)
    pipe = DataPipeline(lambda s, sh: synthetic_lm_batch(cfg, s, sh), prefetch=2)
    s0, b0 = pipe.next()
    s1, b1 = pipe.next()
    assert (s0, s1) == (0, 1)
    pipe.seek(10)
    s10, b10 = pipe.next()
    assert s10 == 10
    assert jnp.array_equal(b10["tokens"], synthetic_lm_batch(cfg, 10, 0)["tokens"])
    pipe.stop()


def test_watchdog_trips_on_straggler():
    wd = StepWatchdog(window=20, trip_factor=2.0)
    import time as _t

    for i in range(12):
        wd.start_step()
        _t.sleep(0.002)
        wd.end_step(i)
    wd.start_step()
    _t.sleep(0.05)
    wd.end_step(99)
    assert wd.trips == 1


def test_preemption_flag():
    h = PreemptionHandler()
    assert not h.preempted()
    h.request_stop()
    assert h.preempted()


def test_retry_eventually_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 42

    assert retry(flaky, attempts=4, backoff_s=0.001) == 42


def test_elastic_plan_preserves_logical_batch():
    p = elastic_plan(logical_batch=256, data_shards=16, max_per_shard=16)
    assert p.per_shard_batch * p.data_shards * p.accumulation_steps == 256
    p2 = elastic_plan(logical_batch=256, data_shards=8, max_per_shard=8)
    assert p2.per_shard_batch * p2.data_shards * p2.accumulation_steps == 256
    assert p2.accumulation_steps > 1
