"""repro.analysis: taint/coverage audits on adversarial fixtures + registry sweep.

The fixtures are deliberately tiny hand-rolled ``loss_with_ctx`` models (the
same contract the clipping engines consume) with one planted defect each:
an injected batch-norm (cross-sample stats), an uncovered param leaf, a
gradient route around a tap, a dead leaf, a declared-but-unthreaded tap.
The sweep tests then assert every *shipped* config audits clean modulo the
documented MoE ``routed_scatter`` allowlist.
"""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    ALLOWLIST,
    audit_arch,
    audit_loss_fn,
    jaxpr_hygiene,
    donation_lint,
)
from repro.analysis import allowlist as allowlist_mod
from repro.analysis.report import FINDINGS_FILENAME, Finding, write_findings
from repro.core.clipping import discover_meta
from repro.configs.registry import ARCHS
from repro.obs.sinks import read_jsonl

B, D_IN, D_H, D_OUT = 3, 5, 7, 2

MOE_ARCHS = {"mixtral-8x7b", "arctic-480b", "jamba-1.5-large-398b"}


def _params(*, sneaky=False, dead=False):
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    p = {
        "lin": {"w": jax.random.normal(k[0], (D_IN, D_H)) * 0.1},
        "out": {"w": jax.random.normal(k[1], (D_H, D_OUT)) * 0.1},
    }
    if sneaky:
        p["sneaky"] = {"w": jax.random.normal(k[2], (D_IN, D_OUT)) * 0.1}
    if dead:
        p["dead"] = {"w": jax.random.normal(k[3], (D_H,)) * 0.1}
    return p


def _batch():
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    return {
        "x": jax.random.normal(kx, (B, D_IN)),
        "y": jax.random.normal(ky, (B, D_OUT)),
    }


def _loss_fn(*, batchnorm=False, sneaky=False, bypass=False):
    """Two tapped matmuls with optional planted defects."""

    def loss(params, batch, ctx):
        x = batch["x"]
        if batchnorm:
            # the BatchNorm failure mode: per-feature stats ACROSS the batch
            x = (x - x.mean(axis=0, keepdims=True)) / jnp.sqrt(
                x.var(axis=0, keepdims=True) + 1e-5
            )
        s = x @ params["lin"]["w"]
        s = ctx.tap(
            "lin", s, kind="matmul", a=x, T=1, D=D_IN, p=D_H, param_path="lin/w"
        )
        h = jax.nn.relu(s)
        if bypass:
            h = h + x @ params["lin"]["w"]  # second use of lin/w, untapped
        o = h @ params["out"]["w"]
        o = ctx.tap(
            "out", o, kind="matmul", a=h, T=1, D=D_H, p=D_OUT, param_path="out/w"
        )
        if sneaky:
            o = o + x @ params["sneaky"]["w"]  # untapped trainable leaf
        return ((o - batch["y"]) ** 2).sum(axis=-1)

    return loss


# -- pass 1: per-sample isolation --------------------------------------------


def test_clean_fixture_audits_clean():
    assert audit_loss_fn(_loss_fn(), _params(), _batch()) == []


def test_injected_batchnorm_caught_with_provenance():
    findings = audit_loss_fn(
        _loss_fn(batchnorm=True), _params(), _batch(), arch="fixture"
    )
    mixed = [f for f in findings if f.code == "sample_mixing"]
    assert mixed, findings
    assert all(f.severity == "error" for f in mixed)
    site = next(f for f in mixed if f.subject == "lin")
    # eqn-level provenance: network input at the root, tap-add site at the tip
    assert site.provenance[0].startswith("batch[x]")
    assert site.provenance[-1].startswith("tap add:")
    assert len(site.provenance) >= 3  # at least one real eqn hop between them


# -- pass 2: gradient-path coverage ------------------------------------------


def test_uncovered_param_named_by_path():
    findings = audit_loss_fn(
        _loss_fn(sneaky=True), _params(sneaky=True), _batch(), arch="fixture"
    )
    assert [
        (f.code, f.severity, f.subject) for f in findings
    ] == [("uncovered_param", "error", "sneaky/w")]


def test_frozen_prefix_waives_uncovered_param():
    findings = audit_loss_fn(
        _loss_fn(sneaky=True),
        _params(sneaky=True),
        _batch(),
        frozen_prefixes=("sneaky",),
    )
    assert findings == []


def test_tap_bypass_detected():
    findings = audit_loss_fn(
        _loss_fn(bypass=True), _params(), _batch(), arch="fixture"
    )
    assert [(f.code, f.severity, f.subject) for f in findings] == [
        ("tap_bypass", "error", "lin")
    ]
    assert "lin/w" in findings[0].detail


def test_dead_param_is_warn_only():
    findings = audit_loss_fn(_loss_fn(), _params(dead=True), _batch())
    assert [(f.code, f.severity, f.subject) for f in findings] == [
        ("dead_param", "warn", "dead/w")
    ]


def test_declared_but_unthreaded_tap_is_error():
    loss, params, batch = _loss_fn(), _params(), _batch()
    meta = dict(discover_meta(loss, params, batch, clip=None))
    meta["ghost"] = meta["lin"]  # declared, never added in the graph
    findings = audit_loss_fn(loss, params, batch, meta=meta)
    assert [(f.code, f.severity, f.subject) for f in findings] == [
        ("tap_unthreaded", "error", "ghost")
    ]


# -- pass 3: tracing hygiene --------------------------------------------------


def test_hygiene_clean_jaxpr():
    closed = jax.make_jaxpr(lambda x: jnp.sin(x) * 2.0)(jnp.ones(3))
    assert jaxpr_hygiene(closed) == []


def test_planted_f64_promotion_detected():
    from jax.experimental import enable_x64

    with enable_x64():
        closed = jax.make_jaxpr(lambda x: jnp.sin(x.astype(jnp.float64)))(
            jnp.ones(3, jnp.float32)
        )
    findings = jaxpr_hygiene(closed, arch="fixture")
    assert any(f.code == "f64_promotion" and f.severity == "warn" for f in findings)


def test_host_callback_in_step_detected():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0

    closed = jax.make_jaxpr(f)(jnp.ones(3))
    findings = jaxpr_hygiene(closed, arch="fixture")
    assert any(f.code == "host_callback" for f in findings)


def test_donation_lint_fixture_tree(tmp_path):
    launch = tmp_path / "src" / "repro" / "launch"
    launch.mkdir(parents=True)
    (launch / "train.py").write_text(
        textwrap.dedent(
            """
            import jax

            jit_step = jax.jit(step_fn).lower(state, batch).compile()
            micro_fn = jax.jit(micro, donate_argnums=(2,)).lower(g, b, acc).compile()
            fin_fn = jax.jit(fin, donate_argnums=(1,)).lower(state).compile()
            """
        )
    )
    findings = donation_lint(repo_root=tmp_path)
    assert all(f.code == "donation_miss" and f.severity == "warn" for f in findings)
    assert sorted(f.subject.rsplit(":", 1)[-1] for f in findings) == [
        "fin_fn",
        "jit_step",
    ]


def test_donation_lint_real_repo_clean():
    assert donation_lint() == []


# -- allowlist + findings plumbing --------------------------------------------


def test_stale_allowlist_entry_warns():
    out, used = allowlist_mod.apply("mixtral-8x7b", [], entries=ALLOWLIST)
    assert used == set()
    assert [(f.code, f.severity) for f in out] == [("stale_allowlist", "warn")]


def test_unknown_finding_code_rejected():
    with pytest.raises(ValueError):
        Finding(code="nope", severity="error", arch="-", subject="s", detail="d")
    with pytest.raises(ValueError):
        Finding(
            code="sample_mixing", severity="fatal", arch="-", subject="s", detail="d"
        )


def test_findings_jsonl_roundtrip(tmp_path):
    findings = [
        Finding(
            code="sample_mixing",
            severity="error",
            arch="fixture",
            subject="lin",
            detail="mixed",
            provenance=("batch[x] (network input)", "tap add: add"),
        ),
        Finding(
            code="f64_promotion",
            severity="warn",
            arch="fixture",
            subject="sin",
            detail="wide",
        ),
    ]
    path = tmp_path / FINDINGS_FILENAME
    write_findings(findings, path)
    recs = read_jsonl(path)
    assert [r["code"] for r in recs] == ["sample_mixing", "f64_promotion"]
    assert recs[0]["kind"] == "finding"
    assert recs[0]["provenance"] == [
        "batch[x] (network input)",
        "tap add: add",
    ]
    assert "provenance" not in recs[1]


# -- registry sweep ------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_registry_config_audits_clean(name):
    findings = audit_arch(name, hygiene_pass=False)
    assert [f for f in findings if f.severity == "error"] == []
    assert [f for f in findings if f.severity == "warn"] == []
    infos = [f for f in findings if f.severity == "info"]
    if name in MOE_ARCHS:
        # the documented waiver must actually be exercised, not silently unused
        assert infos
        assert all(
            f.code == "routed_scatter" and f.allowlisted_by for f in infos
        )
    else:
        assert infos == []


def test_allowlist_off_surfaces_moe_error():
    findings = audit_arch(
        "mixtral-8x7b", hygiene_pass=False, apply_allowlist=False
    )
    errors = [f for f in findings if f.severity == "error"]
    assert errors
    assert all(f.code == "routed_scatter" for f in errors)


def test_step_hygiene_clean_end_to_end():
    # full audit including the jitted-train-step hygiene pass on one config
    assert audit_arch("yi-6b") == []


def test_cli_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main

    assert main(["--arch", "yi-6b", "--no-hygiene"]) == 0
    assert "0 error(s)" in capsys.readouterr().out

    rc = main(
        [
            "--arch",
            "mixtral-8x7b",
            "--no-hygiene",
            "--no-allowlist",
            "--out",
            str(tmp_path),
        ]
    )
    assert rc == 1
    capsys.readouterr()
    recs = read_jsonl(tmp_path / FINDINGS_FILENAME)
    assert any(r["code"] == "routed_scatter" for r in recs)
