import os

# Smoke tests and benches must see the single real CPU device (the dry-run
# sets its own 512-device flag inside launch/dryrun.py only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _reset_obs_sinks():
    # the obs sink registry is process-wide; a test that configures a run
    # (directly or via launch.train main) must not leak sinks into the next
    from repro.obs import events, sinks

    yield
    sinks.reset_sinks()
    events.set_run_context(None)
