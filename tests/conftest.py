import os

# Smoke tests and benches must see the single real CPU device (the dry-run
# sets its own 512-device flag inside launch/dryrun.py only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
