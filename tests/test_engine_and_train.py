"""PrivacyEngine integration + train-loop fault tolerance."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import build_model, get_arch
from repro.core.engine import PrivacyEngine
from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch


def _engine(model, mode="mixed_ghost", **kw):
    defaults = dict(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=10_000,
        steps=100, max_grad_norm=0.5, noise_multiplier=1.0, mode=mode,
    )
    defaults.update(kw)
    return PrivacyEngine(**defaults)


def test_engine_sigma_from_epsilon():
    model = build_model(get_arch("yi-6b").reduced())
    e = _engine(model, noise_multiplier=None, target_epsilon=2.0)
    assert e.noise_multiplier > 0.3
    eps, delta = e.privacy_spent(steps=100)
    assert eps <= 2.0 + 1e-6


def test_engine_clip_noise_pipeline():
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = _engine(model)
    data = SyntheticLMConfig(vocab=cfg.vocab, seq_len=12, batch=4)
    batch = synthetic_lm_batch(data, 0)
    engine.validate(params, batch)
    loss, gsum, aux = jax.jit(engine.clipped_grad_fn())(params, batch)
    assert jnp.isfinite(loss)
    # per-sample contributions bounded by R
    assert bool(jnp.all(aux["clip_factors"] * aux["per_sample_norms"]
                        <= engine.max_grad_norm * 1.001))
    g1 = engine.privatize(gsum, jax.random.PRNGKey(1))
    g2 = engine.privatize(gsum, jax.random.PRNGKey(2))
    # noise actually applied and key-dependent
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
    assert d > 0
    # accounting moves
    engine.record_step(10)
    eps10 = engine.accountant.get_epsilon(engine.target_delta)
    engine.record_step(10)
    assert engine.accountant.get_epsilon(engine.target_delta) > eps10


def test_train_cli_resume_and_fault_injection(tmp_path):
    from repro.launch.train import main

    argv = [
        "--arch", "yi-6b", "--reduced", "--steps", "8", "--batch", "2",
        "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
        "--fail-at-step", "5", "--auto-restart", "2", "--log-every", "4",
    ]
    assert main(argv) == 0
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 8


def test_train_cli_resume_from_pre_policy_checkpoint(tmp_path):
    """A checkpoint written before the policies subsystem (no state["policy"]
    subtree) must resume: the missing policy state is filled with init."""
    import numpy as np

    from repro.launch.train import main

    base = [
        "--arch", "yi-6b", "--reduced", "--batch", "2", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--log-every", "2",
        "--clip-policy", "quantile",
    ]
    assert main(base + ["--steps", "2"]) == 0
    # simulate a legacy artifact: strip the policy/* leaves in place
    path = tmp_path / "step_2.npz"
    with np.load(path) as z:
        legacy = {k: z[k] for k in z.files if not k.startswith("policy/")}
    np.savez(path, **legacy)
    assert main(base + ["--steps", "4", "--resume"]) == 0
    with np.load(tmp_path / "step_4.npz") as z:
        # the filled-in policy state adapted over the resumed steps
        assert "policy/clip_norm" in z.files
        assert int(z["policy/step"]) == 2


def test_train_cli_poisson(tmp_path):
    from repro.launch.train import main

    argv = [
        "--arch", "xlstm-350m", "--reduced", "--steps", "3", "--batch", "2",
        "--seq", "16", "--poisson", "--log-every", "1",
    ]
    assert main(argv) == 0


def test_train_cli_accumulation_path(tmp_path, monkeypatch):
    """--tune with a hi-cap of 1 forces physical=1, accum=2: the donated-
    accumulator loop must run end-to-end (init/micro/finalize AOT programs,
    one host sync per logical batch) and checkpoint at the requested step."""
    from repro.checkpoint import latest_step
    from repro.launch.train import main

    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "plans"))
    argv = [
        "--arch", "xlstm-350m", "--reduced", "--steps", "2", "--batch", "2",
        "--seq", "16", "--tune", "--tune-hi-cap", "1", "--log-every", "1",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ]
    assert main(argv) == 0
    assert latest_step(tmp_path) == 2
