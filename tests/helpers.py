"""Shared test utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def max_tree_diff(a, b) -> float:
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def lm_batch(key, batch, seq, vocab):
    k1, k2 = jax.random.split(key)
    return {
        "tokens": jax.random.randint(k1, (batch, seq), 0, vocab, dtype=jnp.int32),
        "labels": jax.random.randint(k2, (batch, seq), 0, vocab, dtype=jnp.int32),
        "mask": jnp.ones((batch,), jnp.float32),
    }
