"""repro.policies: the policy x executor-mode exactness oracle, quantile
accounting, plan/consensus policy gates, and checkpoint round-trips.

The central claims:
- every policy (fixed / automatic / quantile / per_layer) produces clipped
  gradients matching a naive per-sample-gradient reference on EVERY executor
  family (vmap / fused second-backward / explicit taps / book-keeping);
- an adversarially flipped-branch tuner plan changes no policy's output
  (branch decisions are policy-independent);
- the quantile policy's indicator release is billed exactly (manual RDP
  composition), including through the target-epsilon bisection;
- policy state survives checkpoint save/restore bit-identically and resumes
  to the same trajectory;
- a fleet cannot agree across ranks running different policies.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.decision import decide
from repro.core.taps import Ctx
from repro.policies import (
    AutomaticPolicy,
    FixedPolicy,
    PerLayerPolicy,
    QuantilePolicy,
    make_policy,
)
from repro.nn.module import Dense, Embedding, RMSNorm
from repro.tuner.plan import ClipPlan, device_string, shape_fingerprint
from repro.utils.tree import flatten_dict

from helpers import lm_batch, max_tree_diff

MODES = ["vmap", "mixed_ghost", "mixed_ghost_taps", "bk_mixed", "bk_mixed_taps"]


class _MLPModel:
    def __init__(self, vocab=17, d=8, f=12, key=jax.random.PRNGKey(0)):
        self.emb = Embedding("emb", vocab, d)
        self.l1 = Dense("l1", d, f, use_bias=True)
        self.norm = RMSNorm("n", f)
        self.l2 = Dense("l2", f, vocab, use_bias=False)
        ks = jax.random.split(key, 4)
        self.params = {
            "emb": self.emb.init(ks[0]), "l1": self.l1.init(ks[1]),
            "n": self.norm.init(ks[2]), "l2": self.l2.init(ks[3]),
        }

    def init(self, key):  # make_train_state contract; deterministic params
        del key
        return self.params

    def loss_with_ctx(self, params, batch, ctx):
        x = self.emb(params["emb"], batch["tokens"], ctx.scope("emb"))
        h = jax.nn.gelu(self.l1(params["l1"], x, ctx.scope("l1")))
        h = self.norm(params["n"], h, ctx.scope("n"))
        logits = self.l2(params["l2"], h, ctx.scope("l2"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
        nll = nll * batch["mask"][:, None]
        return jnp.mean(nll, axis=-1)


def _setup(mask=(1.0, 1.0, 0.0, 1.0)):
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 4, 6, 17)
    batch["mask"] = jnp.asarray(mask)
    return m, m.params, batch


def _per_sample_grads(m, params, batch):
    def single(p, ex):
        return m.loss_with_ctx(p, ex, Ctx.disabled())[0]

    per_ex = jax.tree_util.tree_map(lambda x: x[:, None], batch)
    return jax.vmap(lambda ex: jax.grad(single)(params, ex))(per_ex)


def _naive_reference(policy, pstate, m, params, batch):
    """Clipped grad sum from raw per-sample grads + hand-written policy math."""
    psg = _per_sample_grads(m, params, batch)
    flat = {k: np.asarray(v, np.float64) for k, v in flatten_dict(psg).items()}
    b = batch["mask"].shape[0]
    leaf_norms2 = {
        k: (v.reshape(b, -1) ** 2).sum(axis=1) for k, v in flat.items()
    }
    norms = np.sqrt(sum(leaf_norms2.values()))
    mask = np.asarray(batch["mask"], np.float64)

    def abadi(n, r):
        return np.minimum(r / np.maximum(n, 1e-12), 1.0)

    if isinstance(policy, PerLayerPolicy):
        th = np.asarray(pstate["thresholds"], np.float64)
        g_norms2 = {}
        for path, n2 in leaf_norms2.items():
            gi = policy.group_of(path)
            g_norms2[gi] = g_norms2.get(gi, 0.0) + n2
        factors = {
            gi: abadi(np.sqrt(n2), th[gi]) * mask for gi, n2 in g_norms2.items()
        }
        out = {
            k: np.einsum("b...,b->...", v, factors[policy.group_of(k)])
            for k, v in flat.items()
        }
    else:
        if isinstance(policy, FixedPolicy):
            c = abadi(norms, policy.clip_norm)
        elif isinstance(policy, AutomaticPolicy):
            c = 1.0 / (norms + policy.gamma)
        elif isinstance(policy, QuantilePolicy):
            c = abadi(norms, float(pstate["clip_norm"]))
        else:
            raise AssertionError(policy)
        c = c * mask
        out = {k: np.einsum("b...,b->...", v, c) for k, v in flat.items()}
    return out


def _policies():
    return {
        "fixed": FixedPolicy(clip_norm=0.3),
        "automatic": AutomaticPolicy(gamma=0.01),
        # non-default state R: proves the factors read the STATE, not R0
        "quantile": QuantilePolicy(init_clip_norm=0.37),
        "per_layer": PerLayerPolicy(groups=("emb", "l1"), clip_norm=0.3),
    }


@pytest.mark.parametrize("name", ["fixed", "automatic", "quantile", "per_layer"])
def test_policy_exactness_across_executors(name):
    """Acceptance oracle: every policy x every executor family == naive."""
    m, params, batch = _setup()
    policy = _policies()[name]
    pstate = policy.init_state()
    ref = _naive_reference(policy, pstate, m, params, batch)
    for mode in MODES:
        fn = jax.jit(dp_value_and_clipped_grad(
            m.loss_with_ctx, ClipConfig(mode=mode, clip_norm=0.3, policy=policy)
        ))
        _, g, aux = fn(params, batch, pstate)
        flat = flatten_dict(g)
        for path, want in ref.items():
            err = float(np.max(np.abs(np.asarray(flat[path], np.float64) - want)))
            assert err < 5e-5, (name, mode, path, err)
        # masked samples contribute zero factors everywhere
        assert float(aux["clip_factors"][2]) == 0.0, (name, mode)


@pytest.mark.parametrize("name", ["fixed", "automatic", "quantile", "per_layer"])
@pytest.mark.parametrize("mode", ["mixed_ghost", "bk_mixed"])
def test_flipped_plan_changes_no_policy_output(name, mode):
    """Acceptance: an adversarially flipped-branch plan is invisible to every
    policy — the plan moves cost, the policy moves factors, never together."""
    m, params, batch = _setup()
    policy = _policies()[name]
    pstate = policy.init_state()
    metas = discover_meta(m.loss_with_ctx, params, batch)

    def flip(branch):
        return "instantiate" if branch == "ghost" else "ghost"

    flipped = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=tuple(
            (n, flip(decide(mm, mode="mixed_ghost")))
            for n, mm in sorted(metas.items()) if mm.kind == "matmul"
        ),
        bk_branches=tuple(
            (n, flip(decide(mm, mode="bk_mixed")))
            for n, mm in sorted(metas.items()) if mm.kind == "matmul"
        ),
        policy_fingerprint=policy.fingerprint(),
    )
    cfg = dict(mode=mode, clip_norm=0.3, policy=policy)
    l1, g1, a1 = dp_value_and_clipped_grad(
        m.loss_with_ctx, ClipConfig(**cfg)
    )(params, batch, pstate)
    l2, g2, a2 = dp_value_and_clipped_grad(
        m.loss_with_ctx, ClipConfig(**cfg, plan=flipped)
    )(params, batch, pstate)
    assert float(l1) == float(l2)
    assert jnp.allclose(a1["clip_factors"], a2["clip_factors"], atol=1e-6)
    assert max_tree_diff(g1, g2) < 1e-5, (name, mode)


# ------------------------------------------------------------- policies --
def test_automatic_sensitivity_bounds_contributions():
    """||C_i g_i|| <= sensitivity() == 1 for automatic clipping."""
    m, params, batch = _setup(mask=(1.0, 1.0, 1.0, 1.0))
    policy = AutomaticPolicy(gamma=0.01)
    fn = dp_value_and_clipped_grad(
        m.loss_with_ctx, ClipConfig(mode="mixed_ghost", policy=policy)
    )
    _, _, aux = fn(params, batch, policy.init_state())
    contrib = aux["clip_factors"] * aux["per_sample_norms"]
    assert float(jnp.max(contrib)) <= policy.sensitivity(policy.init_state()) + 1e-6


def test_quantile_update_tracks_target_quantile():
    """Noise-free updates converge R to the target quantile of the norms."""
    policy = QuantilePolicy(
        target_quantile=0.75, lr=0.3, release_sigma=0.0, init_clip_norm=1.0
    )
    norms = jnp.asarray([0.5, 1.5, 2.5, 3.5, 4.5, 5.5, 6.5, 7.5])
    state = policy.init_state()
    for _ in range(200):
        state, ev = policy.update(state, norms)
    assert not ev.spends  # sigma=0: free, and NOT differentially private
    r = float(state["clip_norm"])
    # the 0.75 quantile of 8 samples sits between the 6th and 7th value
    assert 5.5 < r < 7.5, r
    assert int(state["step"]) == 200


def test_quantile_update_respects_mask():
    """Masked-out samples must not count as 'below R' (they have norm 0)."""
    policy = QuantilePolicy(target_quantile=0.5, lr=0.2, release_sigma=0.0)
    norms = jnp.asarray([10.0, 10.0, 0.0, 0.0])
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    s0 = policy.init_state()
    s_masked, _ = policy.update(s0, norms, mask=mask)
    s_unmasked, _ = policy.update(s0, norms)
    # with the mask, nothing is below R=1 -> b=0 -> R grows by exp(lr*q);
    # without it the two zero-norm fakes push b to 0.5 -> R stays put
    assert float(s_masked["clip_norm"]) > float(s_unmasked["clip_norm"])


def test_quantile_needs_key_when_noised():
    policy = QuantilePolicy(release_sigma=1.0)
    with pytest.raises(ValueError):
        policy.update(policy.init_state(), jnp.ones((4,)))


@pytest.mark.parametrize("mode", ["bk_mixed", "mixed_ghost", "vmap"])
def test_per_layer_group_split_raises_at_trace(mode):
    """A group boundary through a tap's (weight, bias) pair must raise — on
    every executor family, including the vmap oracle (whose per-leaf norms
    could otherwise silently accept semantics no other mode reproduces)."""
    m, params, batch = _setup()
    policy = PerLayerPolicy(groups=("l1/w",), clip_norm=0.3)
    fn = dp_value_and_clipped_grad(
        m.loss_with_ctx, ClipConfig(mode=mode, policy=policy)
    )
    with pytest.raises(ValueError, match="different groups"):
        fn(params, batch, policy.init_state())


def test_per_layer_threshold_budget():
    """sum R_g^2 == R^2 (equal split incl. catch-all), sensitivity == R."""
    policy = PerLayerPolicy(groups=("a", "b"), clip_norm=2.0)
    st = policy.init_state()
    th = np.asarray(st["thresholds"])
    assert th.shape == (3,)  # a, b, catch-all
    assert abs(float((th ** 2).sum()) - 4.0) < 1e-6
    assert abs(float(policy.sensitivity(st)) - 2.0) < 1e-5


def test_make_policy_filters_kwargs():
    p = make_policy("automatic", clip_norm=9.0, gamma=0.5, groups=("x",))
    assert isinstance(p, AutomaticPolicy) and p.gamma == 0.5
    with pytest.raises(ValueError, match="unknown clip policy"):
        make_policy("nope")


# ----------------------------------------------------------- accounting --
def test_quantile_epsilon_matches_manual_composition():
    """Acceptance: reported epsilon == manual {gradient + release} RDP."""
    from repro.core.accountant import (
        DEFAULT_ALPHAS,
        eps_from_rdp,
        rdp_subsampled_gaussian,
    )
    from repro.core.engine import PrivacyEngine

    def loss(params, batch, ctx):
        raise NotImplementedError  # accounting only

    kw = dict(loss_with_ctx=loss, batch_size=8, sample_size=10_000,
              steps=64, max_grad_norm=1.0, noise_multiplier=1.3)
    eng = PrivacyEngine(**kw, clip_policy=QuantilePolicy(release_sigma=0.7))
    fixed = PrivacyEngine(**kw)
    eps, delta = eng.privacy_spent(steps=64)
    q = eng.sampling_rate
    rdp = 64 * (rdp_subsampled_gaussian(q, 1.3, DEFAULT_ALPHAS)
                + rdp_subsampled_gaussian(q, 0.7, DEFAULT_ALPHAS))
    assert eps == pytest.approx(eps_from_rdp(rdp, DEFAULT_ALPHAS, delta)[0], abs=1e-12)
    # strictly more than the gradient mechanism alone
    assert eps > fixed.privacy_spent(steps=64)[0]
    # the step-recorded path composes identically
    eng.record_step(64)
    assert eng.accountant.get_epsilon(delta) == pytest.approx(eps, abs=1e-9)
    # a release-free quantile policy spends exactly like fixed
    free = PrivacyEngine(**kw, clip_policy=QuantilePolicy(release_sigma=0.0))
    assert free.privacy_spent(steps=64)[0] == pytest.approx(
        fixed.privacy_spent(steps=64)[0], abs=1e-12
    )


def test_target_epsilon_bisection_composes_release():
    """--target-epsilon convenience: sigma lands the TOTAL spend (gradient +
    quantile release) on the target, instead of needing a hand-picked sigma
    with headroom guessed for the release."""
    from repro.core.engine import PrivacyEngine

    def loss(params, batch, ctx):
        raise NotImplementedError

    kw = dict(loss_with_ctx=loss, batch_size=8, sample_size=10_000,
              steps=64, max_grad_norm=1.0, target_epsilon=2.0)
    eng_q = PrivacyEngine(**kw, clip_policy=QuantilePolicy(release_sigma=0.7))
    eng_f = PrivacyEngine(**kw)
    # the release costs budget, so the gradient mechanism must be noisier
    assert eng_q.noise_multiplier > eng_f.noise_multiplier
    eps_q, _ = eng_q.privacy_spent(steps=64)
    assert eps_q <= 2.0 + 1e-6  # total spend (incl. release) meets the target


# ------------------------------------------------- checkpoint round-trip --
def _tiny_train(policy, steps, tmp_path=None, save_at=None, resume_from=None):
    """Run the real jitted train step; optionally snapshot/restore."""
    from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint
    from repro.launch.steps import DPTrainConfig, make_train_state, make_train_step
    from repro.optim import adam, warmup_cosine

    m = _MLPModel()
    opt = adam()
    dp = DPTrainConfig(
        clipping_mode="bk_mixed", clip_norm=1.0, noise_multiplier=0.8,
        logical_batch=4, policy=policy,
    )
    step_fn = jax.jit(make_train_step(m, opt, warmup_cosine(1e-3, 2, 10), dp))
    if resume_from is not None:
        _, state = restore_checkpoint(resume_from)
        start = int(state["step"])
    else:
        state = make_train_state(m, jax.random.PRNGKey(0), opt, policy)
        start = 0
    for i in range(start, steps):
        batch = lm_batch(jax.random.fold_in(jax.random.PRNGKey(7), i), 4, 6, 17)
        batch["mask"] = jnp.ones((4,))
        state, _ = step_fn(state, batch)
        if save_at is not None and i + 1 == save_at:
            save_checkpoint(tmp_path, i + 1, state)
    return state


@pytest.mark.parametrize("name", ["quantile", "per_layer"])
def test_policy_state_checkpoint_roundtrip_and_resume(name, tmp_path):
    """Acceptance: quantile R / per-layer thresholds survive save/restore
    and a resumed run reproduces the uninterrupted trajectory bit-exactly."""
    policies = {
        "quantile": lambda: QuantilePolicy(
            target_quantile=0.6, release_sigma=0.4, init_clip_norm=1.0
        ),
        "per_layer": lambda: PerLayerPolicy(groups=("emb",), clip_norm=1.0),
    }
    straight = _tiny_train(policies[name](), steps=4)
    _tiny_train(policies[name](), steps=2, tmp_path=tmp_path, save_at=2)
    resumed = _tiny_train(policies[name](), steps=4, resume_from=tmp_path)
    # the policy state itself: bit-identical across the save/restore seam
    for k, v in flatten_dict(straight["policy"]).items():
        rv = flatten_dict(resumed["policy"])[k]
        assert np.array_equal(np.asarray(v), np.asarray(rv)), (name, k)
    # and it actually adapted (stateful policies must not be frozen)
    if name == "quantile":
        assert float(straight["policy"]["clip_norm"]) != 1.0
    assert int(straight["policy"]["step"]) == 4
    # the whole trajectory (params included) is reproduced
    assert max_tree_diff(straight["params"], resumed["params"]) == 0.0


# ------------------------------------------------------ plan / consensus --
def test_policy_fingerprint_changes_consensus_hash():
    base = ClipPlan(fingerprint="ab" * 8, device=device_string())
    stamped = dataclasses.replace(base, policy_fingerprint="quantile:q=0.5")
    other = dataclasses.replace(base, policy_fingerprint="fixed:R=1")
    assert base.consensus_hash() != stamped.consensus_hash()
    assert stamped.consensus_hash() != other.consensus_hash()
    # round-trips through JSON
    assert ClipPlan.from_json(stamped.to_json()).policy_fingerprint == "quantile:q=0.5"


def test_fleet_rejects_mixed_policy_fingerprints():
    from repro.tuner.consensus import PlanConsensusError, RankReport, agree

    m, params, batch = _setup()
    metas = discover_meta(m.loss_with_ctx, params, batch)
    fp = shape_fingerprint(metas)
    plan = ClipPlan(
        fingerprint=fp, device=device_string(),
        policy_fingerprint="quantile:q=0.5",
    )
    mixed = [
        RankReport(0, device_string(), fp, plan.to_json(), None,
                   policy="quantile:q=0.5"),
        RankReport(1, device_string(), fp, None, None, policy="fixed:R=1"),
    ]
    with pytest.raises(PlanConsensusError, match="clipping-policy"):
        agree(mixed)
    uniform = [
        RankReport(0, device_string(), fp, plan.to_json(), None,
                   policy="quantile:q=0.5"),
        RankReport(1, device_string(), fp, None, None, policy="quantile:q=0.5"),
    ]
    adopted = agree(uniform)
    assert adopted.policy_fingerprint == "quantile:q=0.5"
    assert adopted.agreed_ranks == 2


def test_verify_adopted_rejects_foreign_policy_stamp():
    from repro.tuner.consensus import PlanConsensusError, verify_adopted

    m, params, batch = _setup()
    metas = discover_meta(m.loss_with_ctx, params, batch)
    plan = ClipPlan(
        fingerprint=shape_fingerprint(metas), device=device_string(),
        policy_fingerprint="per_layer:groups=emb|",
    )
    verify_adopted(plan, metas)  # no expectation: fine
    verify_adopted(plan, metas, policy_fingerprint="per_layer:groups=emb|")
    with pytest.raises(PlanConsensusError, match="policy"):
        verify_adopted(plan, metas, policy_fingerprint="fixed:R=1")
    # unstamped (pre-v4) plans are accepted under any policy
    bare = dataclasses.replace(plan, policy_fingerprint="")
    verify_adopted(bare, metas, policy_fingerprint="fixed:R=1")


def test_engine_tune_stamps_policy_fingerprint(tmp_path):
    from repro.core.engine import PrivacyEngine
    from repro.tuner.measure import MeasureConfig

    m, params, batch = _setup()
    policy = QuantilePolicy(target_quantile=0.8)
    eng = PrivacyEngine(
        loss_with_ctx=m.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
        clip_policy=policy,
    )
    plan = eng.tune(
        params, batch, arch="mlp-pol", search_max_batch=False,
        measure=MeasureConfig(repeats=1, warmup=1),
        plan_path=str(tmp_path / "p.json"), use_cache=False,
    )
    assert plan.policy_fingerprint == policy.fingerprint()
    assert ClipPlan.load(str(tmp_path / "p.json")).policy_fingerprint == \
        policy.fingerprint()
    # consensus on a single process: the agreed plan keeps the stamp and
    # certifies under the same policy
    plan2 = eng.tune(
        params, batch, arch="mlp-pol", search_max_batch=False,
        measure=MeasureConfig(repeats=1, warmup=1),
        plan_path=str(tmp_path / "p.json"), consensus=True,
    )
    assert plan2.policy_fingerprint == policy.fingerprint()
    assert plan2.agreed_ranks == 1


# ------------------------- quantile denominator under Poisson subsampling --
def test_quantile_denominator_is_static_under_poisson_mask():
    """b_t divides by the STATIC batch shape, never the (private) mask sum.

    With 3 of 8 samples Poisson-selected and every selected norm below R,
    a mask-sum denominator would say b=1.0 (quantile reached); the
    data-independent denominator says b=3/8.  The update must match the
    closed form exactly.
    """
    q, lr = 0.5, 0.2
    policy = QuantilePolicy(target_quantile=q, lr=lr, release_sigma=0.0,
                            init_clip_norm=1.0)
    norms = jnp.asarray([0.1, 0.2, 0.3, 9.0, 9.0, 9.0, 9.0, 9.0])
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    state, _ = policy.update(policy.init_state(), norms, mask=mask)
    expected = 1.0 * np.exp(-lr * (3.0 / 8.0 - q))
    np.testing.assert_allclose(float(state["clip_norm"]), expected, rtol=1e-6)


@pytest.mark.parametrize("physical", [1, 2])
def test_quantile_empty_poisson_round_at_tiny_batch(physical):
    """A tiny physical batch where Poisson sampled NOTHING: b=0 and R grows
    by exactly exp(lr*q) — no NaN from an empty-mask denominator."""
    q, lr = 0.6, 0.25
    policy = QuantilePolicy(target_quantile=q, lr=lr, release_sigma=0.0,
                            init_clip_norm=2.0)
    norms = jnp.full((physical,), 0.5)  # below R, but masked out
    mask = jnp.zeros((physical,))
    state, _ = policy.update(policy.init_state(), norms, mask=mask)
    r = float(state["clip_norm"])
    assert np.isfinite(r)
    np.testing.assert_allclose(r, 2.0 * np.exp(lr * q), rtol=1e-6)


def test_quantile_scattered_logical_batch_matches_direct_update():
    """The accumulation path scatters per-microbatch norms/masks into one
    flat logical-batch buffer (launch.steps.make_accum_microstep) and
    updates once; the result must equal a direct update on the concatenated
    batch, in any microbatch order (the count is permutation-invariant)."""
    policy = QuantilePolicy(target_quantile=0.5, lr=0.2, release_sigma=0.0,
                            init_clip_norm=1.0)
    key = jax.random.PRNGKey(3)
    norms = jax.random.uniform(key, (8,), minval=0.0, maxval=2.0)
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (8,)) < 0.4).astype(
        jnp.float32
    )
    s0 = policy.init_state()
    direct, _ = policy.update(s0, norms, mask=mask)
    # scatter microbatches of 2 into the flat buffers, reversed order
    flat_n = jnp.zeros((8,))
    flat_m = jnp.zeros((8,))
    for i in reversed(range(4)):
        off = (i * 2,)
        flat_n = jax.lax.dynamic_update_slice(flat_n, norms[i * 2:i * 2 + 2], off)
        flat_m = jax.lax.dynamic_update_slice(flat_m, mask[i * 2:i * 2 + 2], off)
    scattered, _ = policy.update(s0, flat_n, mask=flat_m)
    np.testing.assert_allclose(
        float(scattered["clip_norm"]), float(direct["clip_norm"]), rtol=1e-7
    )
