"""End-to-end driver: DP-train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on one host: mixed-ghost clipping,
Poisson subsampling, gradient accumulation (virtual steps), checkpointing,
accounting, watchdog.

    PYTHONPATH=src python examples/train_dp_lm.py --steps 300

On CPU ~1-3 s/step at the default sizes; pass --tiny for a 30-second smoke.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.engine import PrivacyEngine
from repro.checkpoint.manager import CheckpointManager
from repro.data.poisson import poisson_sample_mask
from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch
from repro.models.lm import DecoderLM
from repro.optim import adam, apply_updates, warmup_cosine
from repro.runtime.fault import StepWatchdog

LM_100M = ArchConfig(
    name="repro-lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_ff=2048,
    vocab=32000,
    dtype="float32",
    param_dtype="float32",
    attn_block_q=128,
    attn_block_kv=128,
    source="example driver (~100M params)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=2, help="virtual steps per update")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/dp_lm_ckpt")
    args = ap.parse_args()

    cfg = LM_100M
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4, n_kv=4,
                                  d_ff=256, vocab=512)
        args.seq, args.steps = 64, 10

    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    logical_batch = args.batch * args.accum
    engine = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx,
        batch_size=logical_batch,
        sample_size=1_000_000,
        steps=args.steps,
        max_grad_norm=1.0,
        noise_multiplier=0.8,
        mode="mixed_ghost",
    )
    data_cfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    engine.validate(params, synthetic_lm_batch(data_cfg, 0))

    grad_fn = jax.jit(engine.clipped_grad_fn())
    opt = adam()
    opt_state = opt.init(params)
    sched = warmup_cosine(3e-4, args.steps // 10, args.steps)
    manager = CheckpointManager(args.ckpt_dir, save_every=100)
    watchdog = StepWatchdog()

    @jax.jit
    def apply(params, opt_state, grads, step):
        upd, opt_state = opt.update(grads, opt_state, params, step, sched(step))
        return apply_updates(params, upd), opt_state

    micro = 0
    for step in range(args.steps):
        watchdog.start_step()
        grad_sum = None
        loss_acc = 0.0
        for k in range(args.accum):  # the paper's virtual_step
            batch = synthetic_lm_batch(data_cfg, micro)
            batch["mask"] = poisson_sample_mask(
                jax.random.fold_in(jax.random.PRNGKey(7), micro),
                args.batch, engine.sampling_rate,
            )
            micro += 1
            loss, g, _ = grad_fn(params, batch)
            loss_acc += float(loss)
            grad_sum = g if grad_sum is None else jax.tree_util.tree_map(
                jnp.add, grad_sum, g
            )
        grads = engine.privatize(
            grad_sum, jax.random.fold_in(jax.random.PRNGKey(13), step)
        )
        params, opt_state = apply(params, opt_state, grads, jnp.asarray(step))
        engine.record_step()
        dt = watchdog.end_step(step)
        if step % 10 == 0 or step == args.steps - 1:
            eps, _ = engine.privacy_spent()
            print(f"step {step}: loss={loss_acc/args.accum:.4f} eps={eps:.3f} "
                  f"({dt:.2f}s/step)")
        manager.save(step, {"params": params, "opt": opt_state})
    manager.save(args.steps, {"params": params, "opt": opt_state}, force=True)
    manager.wait()
    eps, delta = engine.privacy_spent()
    print(f"final: eps={eps:.3f} delta={delta:.1e}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
