"""Continuous-batching serving demo on ``repro.serving.Engine``.

Submits a mixed-length request stream (some with TTFT SLOs), drains the
engine, and prints per-request latency plus the aggregate benchmark row.
A finished slot is recycled to the next queued request on the very next
decode step — watch the ``steps`` count stay far below requests x max_new.

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.serving import Engine, aggregate_metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--slo-ttft-ms", type=float, default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = Engine(
        model, params,
        n_slots=args.slots,
        page_size=8,
        max_len=args.max_prompt + args.max_new,
        eos_id=0,
    )

    key = jax.random.PRNGKey(100)
    for i in range(args.requests):
        key, k1, k2 = jax.random.split(key, 3)
        plen = int(jax.random.randint(k1, (), 4, args.max_prompt + 1))
        prompt = (1 + jax.random.randint(
            k2, (plen,), 0, cfg.vocab - 1, dtype=jnp.int32)).tolist()
        engine.submit(prompt, max_new=args.max_new,
                      slo_ttft_ms=args.slo_ttft_ms)

    completions = engine.drain()
    for rid in sorted(completions):
        c = completions[rid]
        ttft = f"{c.ttft_s * 1e3:6.1f}ms" if c.ttft_s is not None else "   shed"
        print(f"request {rid}: prompt={c.prompt_len:3d} finish={c.finish:6s} "
              f"ttft={ttft} tokens={c.tokens}")

    m = aggregate_metrics(completions)
    print(f"\n{int(m['requests'])} served / {int(m['shed'])} shed in "
          f"{engine.steps} engine steps: {int(m['tokens'])} tokens, "
          f"{m['tok_per_s']:.1f} tok/s, "
          f"TTFT p95 {m['ttft_p95_ms']:.1f}ms, "
          f"per-token p95 {m['per_token_p95_ms']:.1f}ms")


if __name__ == "__main__":
    main()
