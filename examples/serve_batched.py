"""Batched serving with per-request completion tracking (continuous-batching
style slot recycling on a fixed decode batch).

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.launch.steps import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(make_decode_step(model))
    prefill = jax.jit(model.prefill)

    pending = list(range(args.requests))
    done = {}
    t0 = time.time()
    total_tokens = 0
    wave = 0
    while pending:
        batch_ids = pending[: args.slots]
        pending = pending[args.slots :]
        toks = jax.random.randint(
            jax.random.PRNGKey(100 + wave), (len(batch_ids), args.prompt_len),
            0, cfg.vocab, dtype=jnp.int32,
        )
        state = model.init_state(len(batch_ids), args.prompt_len + args.max_new)
        logits, state = prefill(params, {"tokens": toks}, state)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        outs = [tok]
        for _ in range(args.max_new - 1):
            tok, _, state = decode(params, tok, state)
            outs.append(tok)
        gen = jnp.concatenate(outs, axis=1)
        total_tokens += int(gen.size)
        for i, rid in enumerate(batch_ids):
            done[rid] = gen[i].tolist()
        wave += 1
    dt = time.time() - t0
    print(f"served {args.requests} requests in {wave} waves, "
          f"{total_tokens} tokens, {total_tokens/dt:.1f} tok/s")
    for rid in sorted(done)[:3]:
        print(f"  request {rid}: {done[rid]}")


if __name__ == "__main__":
    main()
