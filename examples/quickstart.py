"""Quickstart: DP training with mixed ghost clipping in ~40 lines.

The JAX analogue of the paper's Appendix-E privacy engine demo:

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.core.engine import PrivacyEngine
from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch
from repro.optim import adam, apply_updates

# 1. any model in the zoo, reduced for CPU
cfg = get_arch("yi-6b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. attach the privacy engine (paper Appendix E, functional style)
engine = PrivacyEngine(
    loss_with_ctx=model.loss_with_ctx,
    batch_size=8,
    sample_size=50_000,
    epochs=3,
    max_grad_norm=0.1,
    target_epsilon=3.0,
    mode="mixed_ghost",  # the paper's 'ghost-mixed'
)
print(f"sigma={engine.noise_multiplier:.3f} for (eps=3, delta={engine.target_delta:.1e})")

data_cfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=64, batch=8)
engine.validate(params, synthetic_lm_batch(data_cfg, 0))  # no param escapes clipping

# 3. the usual train loop; gradients come pre-clipped, privatize() adds noise
grad_fn = jax.jit(engine.clipped_grad_fn())
opt = adam()
opt_state = opt.init(params)
for step in range(10):
    batch = synthetic_lm_batch(data_cfg, step)
    loss, grad_sum, aux = grad_fn(params, batch)
    grads = engine.privatize(grad_sum, jax.random.fold_in(jax.random.PRNGKey(1), step))
    updates, opt_state = opt.update(grads, opt_state, params, jnp.asarray(step), 1e-3)
    params = apply_updates(params, updates)
    engine.record_step()
    print(f"step {step}: loss={float(loss):.4f} "
          f"median_grad_norm={float(jnp.median(aux['per_sample_norms'])):.2f}")

eps, delta = engine.privacy_spent()
print(f"privacy spent: eps={eps:.3f} delta={delta:.1e}")
