"""The paper's home ground: DP-train a CNN (VGG-style) on image data with
mixed ghost clipping, and show the layerwise decision the engine made.

    PYTHONPATH=src python examples/dp_finetune_cnn.py
"""
import jax
import jax.numpy as jnp

from repro.core.clipping import discover_meta
from repro.core.decision import decide
from repro.core.engine import PrivacyEngine
from repro.data.synthetic import synthetic_vision_batch
from repro.models.cnn import VGG
from repro.optim import adam, apply_updates

model = VGG("vgg11", n_classes=10)
params = model.init(jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"VGG-11 (GroupNorm), {n/1e6:.2f}M params")

batch_fn = lambda step: synthetic_vision_batch(
    batch=16, image=32, channels=3, n_classes=10, step=step
)

engine = PrivacyEngine(
    loss_with_ctx=model.loss_with_ctx,
    batch_size=16,
    sample_size=50_000,
    epochs=1,
    max_grad_norm=0.1,
    target_epsilon=2.0,
    mode="mixed_ghost",
)
engine.validate(params, batch_fn(0))

# show the paper's Table-3-style layerwise decision for THIS model/input
meta = discover_meta(model.loss_with_ctx, params, batch_fn(0))
print("\nlayerwise decision (Eq 4.1):")
for name, m in sorted(meta.items()):
    if m.kind == "matmul":
        print(f"  {name:22s} T={m.T:5d} D={m.D:6d} p={m.p:5d} "
              f"-> {decide(m, mode='mixed_ghost')}")

grad_fn = jax.jit(engine.clipped_grad_fn())
opt = adam()
opt_state = opt.init(params)
print()
for step in range(12):
    batch = batch_fn(step)
    loss, gsum, aux = grad_fn(params, batch)
    grads = engine.privatize(gsum, jax.random.fold_in(jax.random.PRNGKey(5), step))
    upd, opt_state = opt.update(grads, opt_state, params, jnp.asarray(step), 5e-3)
    params = apply_updates(params, upd)
    engine.record_step()
    if step % 3 == 0:
        print(f"step {step}: loss={float(loss):.4f} "
              f"clip_frac={float(jnp.mean((aux['clip_factors'] < 1))):.2f}")
eps, delta = engine.privacy_spent()
print(f"\nprivacy spent: eps={eps:.3f}, delta={delta:.1e}")
