"""The paper's home ground: DP-train a CNN (VGG-style) on image data with
mixed ghost clipping, and show the layerwise decision the engine made.

    PYTHONPATH=src python examples/dp_finetune_cnn.py

Tuner quickstart: ``--tune`` replaces the analytic Eq-(4.1) decision with
branches *measured* on this device (repro.tuner) and prints both, flagging
taps where the hardware disagrees with the model.  The tuned ClipPlan is
cached, so a second run skips profiling; ``--plan path.json`` pins the cache
location.

    PYTHONPATH=src python examples/dp_finetune_cnn.py --tune
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.clipping import discover_meta
from repro.core.decision import decide
from repro.core.engine import PrivacyEngine
from repro.data.synthetic import synthetic_vision_batch
from repro.models.cnn import VGG
from repro.optim import adam, apply_updates
from repro.tuner import MeasureConfig

ap = argparse.ArgumentParser()
ap.add_argument("--tune", action="store_true",
                help="profile ghost vs instantiate per tap on this device")
ap.add_argument("--plan", default=None, help="ClipPlan path (default: cache)")
args = ap.parse_args()

model = VGG("vgg11", n_classes=10)
params = model.init(jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"VGG-11 (GroupNorm), {n/1e6:.2f}M params")

batch_fn = lambda step: synthetic_vision_batch(
    batch=16, image=32, channels=3, n_classes=10, step=step
)

engine = PrivacyEngine(
    loss_with_ctx=model.loss_with_ctx,
    batch_size=16,
    sample_size=50_000,
    epochs=1,
    max_grad_norm=0.1,
    target_epsilon=2.0,
    mode="mixed_ghost",
)
engine.validate(params, batch_fn(0))

# show the paper's Table-3-style layerwise decision for THIS model/input
meta = discover_meta(model.loss_with_ctx, params, batch_fn(0))

measured = {}
if args.tune:
    # measured-cost autotuning: time both branches per tap on this device,
    # search the max physical microbatch, cache the ClipPlan
    plan = engine.tune(
        params, batch_fn(0), arch="vgg11-cifar",
        measure=MeasureConfig(repeats=3, warmup=1),
        hi_cap=256,
        plan_path=args.plan if args.plan else "auto",
    )
    measured = plan.branch_map()
    print(f"\ntuned on {plan.device}: max physical batch = {plan.physical_batch}")
    print(f"three-way verdict: mixed_ghost={plan.mode_cost_us('mixed_ghost'):.0f}us "
          f"bk_mixed={plan.mode_cost_us('bk_mixed'):.0f}us per step "
          f"-> recommended mode: {plan.recommended_mode()}")

print("\nlayerwise decision (Eq 4.1%s):" % (" vs measured" if measured else ""))
for name, m in sorted(meta.items()):
    if m.kind == "matmul":
        analytic = decide(m, mode="mixed_ghost")
        line = (f"  {name:22s} T={m.T:5d} D={m.D:6d} p={m.p:5d} -> {analytic}")
        if name in measured:
            flip = "  <- flip" if measured[name] != analytic else ""
            line += f"  (measured: {measured[name]}){flip}"
        print(line)

grad_fn = jax.jit(engine.clipped_grad_fn())
opt = adam()
opt_state = opt.init(params)
print()
for step in range(12):
    batch = batch_fn(step)
    loss, gsum, aux = grad_fn(params, batch)
    grads = engine.privatize(gsum, jax.random.fold_in(jax.random.PRNGKey(5), step))
    upd, opt_state = opt.update(grads, opt_state, params, jnp.asarray(step), 5e-3)
    params = apply_updates(params, upd)
    engine.record_step()
    if step % 3 == 0:
        print(f"step {step}: loss={float(loss):.4f} "
              f"clip_frac={float(jnp.mean((aux['clip_factors'] < 1))):.2f}")
eps, delta = engine.privacy_spent()
print(f"\nprivacy spent: eps={eps:.3f}, delta={delta:.1e}")
